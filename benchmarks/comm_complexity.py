"""Paper Table 1: communication complexity.

Three views:
  (a) MEASURED collective bytes per training iteration, parsed from the
      compiled production-mesh HLO (qwen2-0.5b on the 16x16 mesh, fused
      flat-buffer backend), for S-SGD (sync every step) vs Local SGD /
      VRL-SGD (sync every k):
          per-iter bytes = local_step_bytes + sync_bytes / k
      The worker-axis term drops by ~k, exactly the paper's mechanism.
  (b) HIERARCHICAL cross-pod bytes on the 2x16x16 multi-pod mesh: the
      level-2 sync (the only event touching the slow DCI tier) runs every
      k2 steps, so cross-pod bytes/iter = sync2_bytes / k2 — vs flat
      VRL-SGD at k1 whose every sync all-reduces the full buffer across
      pods: cross-pod bytes/iter = sync_bytes / k1.  The ratio is k2/k1
      with identical intra-pod cadence.
  (c) ASYMPTOTIC communication rounds at the paper's own scale
      (T=117,187 iterations, N=8 workers, paper §F):
          S-SGD      T                    = 117,187
          Local SGD  T / (T^1/4 N^-3/4)   = T^{3/4} N^{3/4}
          VRL-SGD    T / (T^1/2 N^-3/2)   = T^{1/2} N^{3/2}
  (d) STAGEWISE bytes-vs-T (STL-SGD): the measured per-sync bytes from (a)
      amortized over a stagewise-doubling CommSchedule — cumulative sync
      bytes at horizon T are rounds(T) · sync_bytes, and the doubling
      period makes rounds(T) grow as O(log T) stages x rounds_per_stage
      instead of T/k, so the curve flattens where constant-k stays linear.
  (e) COMPRESSED bytes-vs-T: the two communication-complexity axes
      composed — measured wire bytes/round (repro.comm: the actual
      compressed representation of qwen2-0.5b's production flat buffer,
      tile padding elided) x rounds(T) per (algorithm cadence, schedule,
      compressor).  Rounds come from the cadence (S-SGD every step,
      constant k, stagewise doubling), bytes/round from the compressor
      (none / int8 / topk) — every cell is their product, which is exactly
      why compression composes multiplicatively with every schedule.
      Cheap (no dry-run shell-out; the flat layout is derived from
      shapes), so CI runs it standalone: ``--view compress``.
  (f) COHORT bytes-vs-participation: with M logical clients sampled at
      participation p, a round's sync all-reduce spans W = p·M cohort
      slots — per-round wire bytes scale with the cohort — while one
      "client epoch" (every client heard once, ≈ M/W rounds) moves the
      SAME total bytes at every p.  Participation trades per-round
      bandwidth against rounds, never total epoch traffic.  Cheap like
      (e): ``--view cohort``.

The measured views shell out to the dry-run driver because the 512-device
placeholder env must be set before jax initializes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import csv
from repro.core import schedule as schedule_mod
from repro.obs import convert as obs_convert

ARCH = "qwen2-0.5b"
K = 20
K1, K2 = 5, 20      # hierarchical periods for view (b)
STAGE_T = (100, 1_000, 10_000, 117_187)   # horizons for view (d)


def _dryrun(fn: str, algorithm: str = "vrl_sgd", out: str = "",
            mesh: str = "single") -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", ARCH,
           "--shape", "train_4k", "--fn", fn, "--mesh", mesh,
           "--algorithm", algorithm, "--out", out,
           "--k1", str(K1), "--k2", str(K2)]
    env = dict(os.environ, PYTHONPATH="src")
    subprocess.run(cmd, env=env, capture_output=True, timeout=1800,
                   check=True)
    with open(out) as f:
        return json.loads(f.readlines()[-1])


def main() -> dict:
    out = {}
    tmp = "results/comm_bench.jsonl"
    os.makedirs("results", exist_ok=True)
    open(tmp, "w").close()
    t0 = time.perf_counter()
    local = _dryrun("local", "vrl_sgd", tmp)
    sync = _dryrun("sync", "vrl_sgd", tmp)
    ssgd = _dryrun("train", "ssgd", tmp)
    us = (time.perf_counter() - t0) * 1e6 / 3

    local_b = local["coll_bytes"]
    sync_b = sync["coll_bytes"]
    ssgd_b = ssgd["coll_bytes"]
    vrl_iter = local_b + sync_b / K
    csv("table1/measured_bytes_per_iter/ssgd", us, f"bytes={ssgd_b:.3e}")
    csv("table1/measured_bytes_per_iter/vrl_sgd_k20", us,
        f"bytes={vrl_iter:.3e};local={local_b:.3e};sync_amortized={sync_b/K:.3e}")
    csv("table1/measured_bytes_per_iter/worker_axis_reduction", 0.0,
        f"sync_vs_ssgd_worker_bytes={(ssgd_b - local_b) / max(sync_b / K, 1):.1f}x")

    # (b) hierarchical cross-pod bytes on the multi-pod mesh
    hier_sync2 = _dryrun("sync2", "hier_vrl_sgd", tmp, mesh="multi")
    flat_sync = _dryrun("sync", "vrl_sgd", tmp, mesh="multi")
    s2_b = hier_sync2["coll_bytes"]
    flat_b = flat_sync["coll_bytes"]
    hier_cross_iter = s2_b / K2
    flat_cross_iter = flat_b / K1
    csv("table1/hier_cross_pod_bytes_per_iter/hier_k1_k2", 0.0,
        f"bytes={hier_cross_iter:.3e};sync2={s2_b:.3e};k1={K1};k2={K2}")
    csv("table1/hier_cross_pod_bytes_per_iter/flat_vrl_k1", 0.0,
        f"bytes={flat_cross_iter:.3e};sync={flat_b:.3e};k1={K1}")
    csv("table1/hier_cross_pod_bytes_per_iter/reduction", 0.0,
        f"flat_over_hier={flat_cross_iter / max(hier_cross_iter, 1):.1f}x"
        f" (expected ~k2/k1 = {K2 / K1:.1f}x)")

    # (c) asymptotic rounds at the paper's scale (T=117187, N=8)
    t_iters, n = 117_187, 8
    rounds = {
        "ssgd": t_iters,
        "local_sgd": int(t_iters ** 0.75 * n ** 0.75),
        "vrl_sgd": int(t_iters ** 0.5 * n ** 1.5),
    }
    for alg, r in rounds.items():
        csv(f"table1/asymptotic_rounds/{alg}", 0.0,
            f"rounds={r};T={t_iters};N={n}")

    # (d) stagewise bytes-vs-T: the measured sync bytes amortized over the
    # STL-SGD doubling schedule vs the constant-k cadence
    stagewise = stagewise_bytes_vs_t(sync_b)

    # (e) compressed bytes-vs-T: wire bytes/round x rounds(T)
    compressed = compressed_bytes_view()
    out.update(measured=dict(ssgd=ssgd_b, vrl_iter=vrl_iter, local=local_b,
                             sync=sync_b),
               hier=dict(cross_pod_iter=hier_cross_iter,
                         flat_cross_pod_iter=flat_cross_iter,
                         sync2=s2_b, flat_sync=flat_b, k1=K1, k2=K2),
               rounds=rounds, stagewise=stagewise, compressed=compressed)
    # canonicalize the raw dry-run rows (scratch channel between the
    # subprocess runs above) onto the schema-versioned obs stream
    with open(tmp) as f:
        raw_rows = [json.loads(ln) for ln in f if ln.strip()]
    obs_convert.write_jsonl(
        obs_convert.records_from_legacy(raw_rows, "comm_bench"), tmp)
    return out


def stagewise_bytes_vs_t(sync_bytes: float, k_max: int = K,
                         horizons=STAGE_T) -> dict:
    """View (d): cumulative sync bytes over a horizon T for the STL-SGD
    stagewise-doubling schedule (1 → k_max) vs constant k = k_max.

    The per-sync byte count is the same single flat all-reduce at every
    stage (measured from the compiled HLO in view (a)); what the schedule
    changes is HOW MANY rounds a horizon costs.  Early on the doubling
    ramp syncs more densely than constant-k (its warm-up); past the ramp
    both pay T/k_max rounds plus the ramp's constant offset, so the
    stagewise curve converges to constant-k from above while buying the
    dense early syncs STL-SGD's convergence proof wants.
    """
    sched = schedule_mod.stagewise_doubling(k0=1, k_max=k_max)
    curve = {}
    for t in horizons:
        n_stage = len(sched.round_sizes(t))
        n_const = t // k_max
        b_stage = n_stage * sync_bytes
        b_const = n_const * sync_bytes
        curve[t] = {"stagewise_rounds": n_stage, "const_rounds": n_const,
                    "stagewise_bytes": b_stage, "const_bytes": b_const}
        csv(f"table1/stagewise_bytes_vs_T/T{t}", 0.0,
            f"stagewise_bytes={b_stage:.3e};const_k{k_max}_bytes="
            f"{b_const:.3e};rounds={n_stage}_vs_{n_const}")
    return {"k_max": k_max, "stages": list(sched.stages),
            "sync_bytes": sync_bytes, "curve": curve}


def compressed_bytes_view(k_max: int = K, horizons=STAGE_T,
                          out_json: str = "results/comm_compress.json"
                          ) -> dict:
    """View (e): measured wire bytes/round x rounds(T) per (algorithm
    cadence, schedule, compressor).

    Wire bytes are MEASURED on the production payload: the qwen2-0.5b flat
    buffer on the single-pod mesh is built (shapes only — no allocation,
    no dry-run shell-out) and ``repro.comm.compress`` produces the actual
    wire representation of a same-shaped payload, counted by
    ``rep_nbytes``.  Rounds(T) come from each cadence exactly as view (d)
    counts them.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.comm import compressors as cc
    from repro.configs import registry
    from repro.core import flat as flat_mod
    from repro.models import transformer

    mesh_cfg = registry.mesh_roles(ARCH, multi_pod=False)
    cfg = registry.padded_arch(ARCH, mesh_cfg)
    template = jax.eval_shape(functools.partial(
        transformer.init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    spec = flat_mod.make_spec(template)
    item = jnp.dtype(spec.dtype).itemsize
    raw = cc.raw_bytes(spec.rows, spec.lanes, item)
    u = cc.used_rows(spec.size, spec.lanes)

    # measured: actual wire representation of a same-shaped payload
    payload = jnp.linspace(-1.0, 1.0, spec.padded,
                           dtype=jnp.float32).reshape(spec.rows, spec.lanes)
    per_round = {"none": raw}
    for name in ("int8", "topk"):
        comp = cc.parse_compressor(name)
        rep = cc.compress(comp, payload, rows_used=u)
        measured = cc.rep_nbytes(rep)
        assert measured == cc.wire_bytes(comp, rows=spec.rows,
                                         lanes=spec.lanes, size=spec.size,
                                         itemsize=item), (name, measured)
        per_round[name] = measured

    sched = schedule_mod.stagewise_doubling(k0=1, k_max=k_max)
    cadences = {
        "ssgd/every_step": lambda t: t,
        f"vrl_sgd/const_k{k_max}": lambda t: t // k_max,
        "stl_sgd/stagewise_doubling": lambda t: len(sched.round_sizes(t)),
    }
    table = {}
    for cad_name, rounds_fn in cadences.items():
        for comp_name, b in per_round.items():
            curve = {}
            for t in horizons:
                r = rounds_fn(t)
                curve[t] = {"rounds": r, "bytes": r * b}
            table[f"{cad_name}/{comp_name}"] = curve
            t_last = horizons[-1]
            csv(f"table1/compressed_bytes_vs_T/{cad_name}/{comp_name}",
                0.0,
                f"bytes_per_round={b:.3e};rounds_T{t_last}="
                f"{rounds_fn(t_last)};bytes_T{t_last}="
                f"{rounds_fn(t_last) * b:.3e}")
    out = {"arch": ARCH, "payload": {
        "rows": spec.rows, "lanes": spec.lanes, "size": spec.size,
        "dtype": spec.dtype, "raw_bytes": raw,
        "wire_bytes_per_round": per_round,
        "reduction": {n: round(raw / b, 2) for n, b in per_round.items()},
    }, "horizons": list(horizons), "table": table}
    if out_json:
        # canonical obs JSONL stream + the legacy .json through the shim
        # (existing artifact consumers read the latter)
        recs = obs_convert.records_from_legacy(out, "comm_compress")
        canon = obs_convert.write_jsonl(
            recs, os.path.splitext(out_json)[0] + ".jsonl")
        obs_convert.write_legacy_json(recs, out_json)
        print(f"wrote {os.path.abspath(canon)} "
              f"(+ legacy {os.path.abspath(out_json)})")
    return out


def cohort_bytes_view(num_clients: int = 256,
                      participation=(0.25, 0.5, 1.0),
                      k_max: int = K,
                      out_json: str = "results/comm_cohort.json") -> dict:
    """View (f): per-round vs per-client-epoch bytes across participation.

    The payload is the same measured qwen2-0.5b flat buffer as view (e).
    Per participant and round the sync moves one payload; a cohort of
    W = p·M moves W payloads per round, and the M/W rounds of a client
    epoch always total M payloads — the participation-invariant.  The
    table also carries the client-store traffic (gather + scatter move
    each cohort row twice over host memory, not the network — reported
    separately so the wire column stays a wire number).
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.comm import compressors as cc
    from repro.configs import registry
    from repro.core import flat as flat_mod
    from repro.models import transformer

    mesh_cfg = registry.mesh_roles(ARCH, multi_pod=False)
    cfg = registry.padded_arch(ARCH, mesh_cfg)
    template = jax.eval_shape(functools.partial(
        transformer.init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    spec = flat_mod.make_spec(template)
    payload = cc.raw_bytes(spec.rows, spec.lanes,
                           jnp.dtype(spec.dtype).itemsize)

    table = {}
    for p in sorted(participation):
        w = max(1, round(p * num_clients))
        rounds_epoch = -(-num_clients // w)           # ceil(M / W)
        row = {
            "workers": w,
            "wire_bytes_per_round": w * payload,
            "rounds_per_client_epoch": rounds_epoch,
            "wire_bytes_per_client_epoch": rounds_epoch * w * payload,
            "store_bytes_per_round": 2 * w * payload,  # gather + scatter
        }
        table[str(p)] = row
        csv(f"table1/cohort_bytes/p{p}", 0.0,
            f"workers={w};bytes_per_round={row['wire_bytes_per_round']:.3e};"
            f"epoch_rounds={rounds_epoch};bytes_per_epoch="
            f"{row['wire_bytes_per_client_epoch']:.3e}")
    out = {"arch": ARCH, "num_clients": num_clients, "k": k_max,
           "payload_bytes": payload, "table": table}
    if out_json:
        recs = obs_convert.records_from_legacy(out, "comm_cohort")
        canon = obs_convert.write_jsonl(
            recs, os.path.splitext(out_json)[0] + ".jsonl")
        obs_convert.write_legacy_json(recs, out_json)
        print(f"wrote {os.path.abspath(canon)} "
              f"(+ legacy {os.path.abspath(out_json)})")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--view", default="all",
                    choices=["all", "compress", "cohort"],
                    help="'compress' runs only view (e), 'cohort' only "
                         "view (f) — no dry-run shell-outs, CI-cheap")
    args = ap.parse_args()
    if args.view == "compress":
        compressed_bytes_view()
    elif args.view == "cohort":
        cohort_bytes_view()
    else:
        main()
