"""Paper Remark 5.3 (VRL-SGD-W): warm-up kills the C term, making
convergence independent of the initial non-iid extent. Derived: final loss
with/without warm-up at high skew."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv, run_mlp_task
from repro.data import feature_classification


def main(steps: int = 240) -> dict:
    data = feature_classification(n=4096, dim=256, num_classes=64, seed=4)
    out = {}
    for warm, tag in [(False, "vrl_sgd"), (True, "vrl_sgd_w")]:
        t0 = time.perf_counter()
        losses = run_mlp_task("vrl_sgd", steps=steps, k=40,
                              partition="class_shard", data=data,
                              warmup=warm)
        us = (time.perf_counter() - t0) / steps * 1e6
        out[tag] = (np.mean(losses[:20]), np.mean(losses[-20:]))
        csv(f"warmup/{tag}", us,
            f"early_loss={out[tag][0]:.4f};final_loss={out[tag][1]:.4f}")
    csv("warmup/summary", 0.0,
        f"warmup_early_gain={out['vrl_sgd'][0] - out['vrl_sgd_w'][0]:.4f}")
    return out


if __name__ == "__main__":
    main()
