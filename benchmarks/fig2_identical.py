"""Paper Fig. 2: epoch loss in the IDENTICAL case — all algorithms should
match. Derived metric: max pairwise final-loss spread."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv, run_mlp_task
from repro.data import feature_classification


def main(steps: int = 300) -> dict:
    data = feature_classification(n=4096, dim=256, num_classes=64, seed=1)
    out = {}
    for alg in ["ssgd", "vrl_sgd", "local_sgd", "easgd"]:
        t0 = time.perf_counter()
        losses = run_mlp_task(alg, steps=steps, k=20, partition="iid",
                              data=data)
        us = (time.perf_counter() - t0) / steps * 1e6
        out[alg] = np.mean(losses[-20:])
        csv(f"fig2_identical/{alg}", us, f"final_loss={out[alg]:.4f}")
    core = {a: v for a, v in out.items() if a != "easgd"}
    spread = max(core.values()) - min(core.values())
    csv("fig2_identical/summary", 0.0,
        f"final_loss_spread_core={spread:.4f};easgd={out['easgd']:.4f}")
    return out


if __name__ == "__main__":
    main()
