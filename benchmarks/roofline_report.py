"""Roofline table from dry-run results (EXPERIMENTS.md §Roofline source).

Reads results/dryrun*.jsonl (written by repro.launch.dryrun / the matrix
script) and prints one CSV row per (arch, shape, mesh) with two-point
calibrated terms: XLA cost_analysis counts a scan body once, so
    per-layer = (2-layer unrolled run) - (scanned run)
    total     = scanned + (num_layers - 1) * per-layer
"""
from __future__ import annotations

import json
import os
from collections import defaultdict

from benchmarks.common import csv
from repro.configs import registry
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

RESULTS = os.environ.get(
    "DRYRUN_RESULTS", "results/dryrun.jsonl,results/dryrun_multi.jsonl")
K = 20


def load(paths: str = RESULTS) -> dict:
    dedup = {}
    for path in paths.split(","):
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    r = json.loads(line)
                    dedup[(r["arch"], r["shape"], r["mesh"], r["fn"])] = r
    return dedup


def main() -> dict:
    rows = load()
    if not rows:
        csv("roofline/missing", 0.0,
            "run scripts/run_dryrun_matrix.sh first")
        return {}
    by_combo = defaultdict(dict)
    for (arch, shape, mesh, fn), r in rows.items():
        if r.get("ok"):
            by_combo[(arch, shape, mesh)][fn] = r
    out = {}
    for (arch, shape, mesh), fns in sorted(by_combo.items()):
        kind = {"train_4k": "local", "prefill_32k": "prefill",
                "decode_32k": "decode", "long_500k": "decode"}[shape]
        scanned = fns.get(kind) or fns.get("train")
        u2 = fns.get(f"{kind}+unroll+u2")
        if scanned is None:
            continue
        L = registry.get_arch(arch).num_layers
        if u2 is not None:
            body_f = max(u2["hlo_flops"] - scanned["hlo_flops"], 0.0)
            body_b = max(u2["hlo_bytes"] - scanned["hlo_bytes"], 0.0)
            flops = scanned["hlo_flops"] + (L - 1) * body_f
            nbytes = scanned["hlo_bytes"] + (L - 1) * body_b
            calib = "u2"
        else:
            flops, nbytes = scanned["hlo_flops"], scanned["hlo_bytes"]
            calib = "scan(body-once)"
        tc = flops / PEAK_FLOPS_BF16
        tm = nbytes / HBM_BW
        tl = scanned["coll_bytes"] / ICI_LINK_BW
        if shape == "train_4k" and "sync" in fns:
            tl += fns["sync"].get("t_collective", 0.0) / K
        bott = max((("compute", tc), ("memory", tm), ("collective", tl)),
                   key=lambda kv: kv[1])[0]
        chips = 256 if mesh == "single" else 512
        useful = scanned["model_flops"] / (flops * chips) if flops else 0.0
        out[(arch, shape, mesh)] = (tc, tm, tl, bott)
        csv(f"roofline/{arch}/{shape}/{mesh}",
            scanned.get("compile_s", 0) * 1e6,
            f"t_compute_ms={tc*1e3:.3f};t_memory_ms={tm*1e3:.3f};"
            f"t_collective_ms={tl*1e3:.3f};bottleneck={bott};"
            f"useful_ratio={useful:.3f};calib={calib};"
            f"mem_gib={scanned.get('per_device_bytes', 0)/2**30:.1f}")
    return out


if __name__ == "__main__":
    main()
