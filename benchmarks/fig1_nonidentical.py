"""Paper Fig. 1: epoch loss in the NON-IDENTICAL case.

Each worker sees a disjoint class subset (the paper's partitioning). Expected
result (paper): VRL-SGD ≈ S-SGD; Local SGD slow; EASGD worst.
Derived metric: final-loss gap to S-SGD (lower = better reproduction).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, run_mlp_task
from repro.data import feature_classification


def main(steps: int = 300) -> dict:
    data = feature_classification(n=4096, dim=256, num_classes=64, seed=0)
    out = {}
    for alg in ["ssgd", "vrl_sgd", "local_sgd", "easgd"]:
        import time
        t0 = time.perf_counter()
        losses = run_mlp_task(alg, steps=steps, k=20,
                              partition="class_shard", data=data)
        us = (time.perf_counter() - t0) / steps * 1e6
        out[alg] = np.mean(losses[-20:])
        csv(f"fig1_nonidentical/{alg}", us,
            f"final_loss={out[alg]:.4f}")
    gap_vrl = out["vrl_sgd"] - out["ssgd"]
    gap_loc = out["local_sgd"] - out["ssgd"]
    csv("fig1_nonidentical/summary", 0.0,
        f"vrl_gap_to_ssgd={gap_vrl:.4f};local_gap_to_ssgd={gap_loc:.4f};"
        f"vrl_beats_local={out['vrl_sgd'] < out['local_sgd']}")
    return out


if __name__ == "__main__":
    main()
