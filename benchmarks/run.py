"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig1_nonidentical    Fig. 1  — non-identical case convergence
  fig2_identical       Fig. 2  — identical case convergence
  appendix_e_quadratic App. E  — exact quadratic (b, k) sweep
  appendix_f_ksweep    App. F  — communication-period sweep
  warmup_ablation      Rmk 5.3 — VRL-SGD-W warm-up
  comm_complexity      Table 1 — measured HLO collective bytes + asymptotics
  step_time            §6.1    — per-step wall-time parity claim
  roofline_report      (ours)  — per (arch x shape x mesh) roofline terms

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
import argparse
import sys
import traceback

from benchmarks import (
    appendix_e_quadratic,
    appendix_f_ksweep,
    comm_complexity,
    fig1_nonidentical,
    fig2_identical,
    roofline_report,
    step_time,
    warmup_ablation,
)

BENCHES = {
    "fig1_nonidentical": lambda fast: fig1_nonidentical.main(
        steps=120 if fast else 300),
    "fig2_identical": lambda fast: fig2_identical.main(
        steps=120 if fast else 300),
    "appendix_e_quadratic": lambda fast: appendix_e_quadratic.main(),
    "appendix_f_ksweep": lambda fast: appendix_f_ksweep.main(
        steps=120 if fast else 240),
    "warmup_ablation": lambda fast: warmup_ablation.main(
        steps=120 if fast else 240),
    "step_time": lambda fast: step_time.main(),
    "roofline_report": lambda fast: roofline_report.main(),
    "comm_complexity": lambda fast: comm_complexity.main(),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            BENCHES[name](args.fast)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
