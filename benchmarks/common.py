"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import VRLConfig
from repro.core import get_algorithm
from repro.data import WorkerLoader, feature_classification
from repro.optim.optimizers import sgd
from repro.train.loss import cross_entropy_cls


def mlp_init(key, in_dim=2048, hidden=1024, classes=200):
    """The paper's transfer-learning model (§6.1)."""
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (in_dim, hidden)) / np.sqrt(in_dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
        "b2": jnp.zeros((classes,)),
    }


def mlp_loss(params, x, y):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return cross_entropy_cls(logits, y)


def run_mlp_task(alg_name: str, *, num_workers=8, batch=32, lr=0.5, k=20,
                 steps=300, partition="class_shard", seed=0,
                 data=None, warmup=False):
    """Paper §6 training protocol on the transfer-learning analog task.

    Returns per-step losses of the average model's mini-batch loss.
    """
    data = data or feature_classification(n=4096, dim=256, num_classes=64,
                                          seed=seed)
    loader = iter(WorkerLoader(data, num_workers, batch, partition=partition,
                               seed=seed))
    cfg = VRLConfig(algorithm=alg_name, comm_period=k, learning_rate=lr,
                    weight_decay=1e-4, warmup=warmup)
    alg = get_algorithm(alg_name)
    params = mlp_init(jax.random.PRNGKey(seed), in_dim=data.x.shape[1],
                      hidden=128, classes=data.num_classes)
    state = alg.init(cfg, params, num_workers)

    def worker_grads(state, xs, ys):
        def per_worker(p, x, y):
            return jax.value_and_grad(mlp_loss)(p, x, y)
        losses, grads = jax.vmap(per_worker)(state.params, xs, ys)
        return grads, jnp.mean(losses)

    @jax.jit
    def step(state, xs, ys):
        grads, _ = worker_grads(state, xs, ys)
        new_state = alg.train_step(cfg, state, grads)
        # the paper's metric: loss of the AVERAGE model on the global batch
        avg = alg.average_model(new_state)
        eval_loss = mlp_loss(avg, xs.reshape(-1, xs.shape[-1]),
                             ys.reshape(-1))
        return new_state, eval_loss

    losses = []
    for _ in range(steps):
        xs, ys = next(loader)
        state, loss = step(state, jnp.asarray(xs), jnp.asarray(ys))
        losses.append(float(loss))
    return losses


def timeit(fn, *args, iters=10, warmup_iters=2):
    for _ in range(warmup_iters):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def timeit_samples(fn, *args, iters=10, warmup_iters=2):
    """Per-iteration wall-clock samples in µs (for p50/p95 tails — a mean
    hides the straggler behavior the overlapped round is built to absorb)."""
    for _ in range(warmup_iters):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return samples


def percentile(samples, q):
    """Nearest-rank percentile of a list of floats (no numpy dependency on
    the caller's side; q in [0, 100])."""
    xs = sorted(samples)
    if not xs:
        return 0.0
    idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx]


def csv(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
