"""Paper Appendix F: influence of the communication period k.

Expected: VRL-SGD tolerates k up to O(T^1/2 / N^3/2) (≈15 at the paper's
scale) while Local SGD degrades past O(T^1/4 / N^3/4) (≈4). Derived: final
loss per (alg, k)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv, run_mlp_task
from repro.data import feature_classification


def main(steps: int = 240) -> dict:
    data = feature_classification(n=4096, dim=256, num_classes=64, seed=2)
    out = {}
    for k in [2, 5, 10, 20, 40, 100]:
        for alg in ["vrl_sgd", "local_sgd"]:
            t0 = time.perf_counter()
            losses = run_mlp_task(alg, steps=steps, k=k,
                                  partition="class_shard", data=data)
            us = (time.perf_counter() - t0) / steps * 1e6
            out[(alg, k)] = np.mean(losses[-20:])
            csv(f"appendix_f/k{k}/{alg}", us,
                f"final_loss={out[(alg, k)]:.4f}")
    # degradation from k=2 to k=100
    deg_vrl = out[("vrl_sgd", 100)] - out[("vrl_sgd", 2)]
    deg_loc = out[("local_sgd", 100)] - out[("local_sgd", 2)]
    csv("appendix_f/summary", 0.0,
        f"vrl_degradation={deg_vrl:.4f};local_degradation={deg_loc:.4f};"
        f"vrl_more_robust={deg_vrl < deg_loc}")
    return out


if __name__ == "__main__":
    main()
