"""Paper Appendix E: the exact quadratic f1=(x+2b)^2, f2=2(x-b)^2 over a
(b, k) sweep. Derived: log10 distance of the average model to the optimum
x*=0 after T steps — VRL-SGD must reach numerical zero for every (b, k);
Local SGD's bias must grow with b and k (paper Fig. 3/4)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv
from repro.configs.base import VRLConfig
from repro.core import get_algorithm


def run(alg_name, b, k, steps=3000, lr=0.02):
    cfg = VRLConfig(algorithm=alg_name, comm_period=k, learning_rate=lr,
                    weight_decay=0.0, warmup=False)
    alg = get_algorithm(alg_name)
    state = alg.init(cfg, {"x": jnp.array([1.0])}, 2)

    @jax.jit
    def step(state):
        x = state.params["x"]
        grads = {"x": jnp.stack([2 * (x[0] + 2 * b), 4 * (x[1] - b)])}
        return alg.train_step(cfg, state, grads)

    for _ in range(steps):
        state = step(state)
    return abs(float(alg.average_model(state)["x"][0]))


def main() -> dict:
    out = {}
    for b in [1.0, 5.0, 25.0]:
        for k in [4, 16, 64]:
            for alg in ["vrl_sgd", "local_sgd"]:
                t0 = time.perf_counter()
                dist = run(alg, b, k)
                us = (time.perf_counter() - t0) * 1e6 / 3000
                out[(alg, b, k)] = dist
                csv(f"appendix_e/b{b:g}_k{k}/{alg}", us,
                    f"log10_dist={np.log10(max(dist, 1e-12)):.2f}")
    ok = all(out[("vrl_sgd", b, k)] < 1e-3 for b in [1.0, 5.0, 25.0]
             for k in [4, 16, 64])
    bias_grows = (out[("local_sgd", 25.0, 64)] > out[("local_sgd", 1.0, 4)])
    csv("appendix_e/summary", 0.0,
        f"vrl_always_converges={ok};local_bias_grows={bias_grows}")
    return out


if __name__ == "__main__":
    main()
